"""Pure-jnp oracles for every Pallas kernel (the correctness contract the
shape/dtype sweep tests assert against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, cache_len, *,
                         sliding_window: int = 0, attention_sinks: int = 0,
                         logit_softcap: float = 0.0) -> jax.Array:
    """q: (B, Hkv, G, hd); caches: HEAD-MAJOR (B, Hkv, S, hd); cache_len:
    (B,). Returns (B, Hkv, G, hd). fp32 math throughout."""
    B, Hkv, G, hd = q.shape
    S = k_cache.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bhgk,bhsk->bhgs", q.astype(jnp.float32) * scale,
                   k_cache.astype(jnp.float32))
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    pos = jnp.arange(S)[None, :]
    valid = pos < cache_len[:, None]
    if sliding_window > 0:
        in_window = pos >= (cache_len[:, None] - sliding_window)
        if attention_sinks > 0:
            in_window |= pos < attention_sinks
        valid &= in_window
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bhsk->bhgk", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, cache_len, *,
                               sliding_window: int = 0,
                               attention_sinks: int = 0,
                               logit_softcap: float = 0.0) -> jax.Array:
    """Oracle for the paged flash-decode kernel: gather the dense head-major
    view through the block table, then the dense oracle math.

    q: (B, Hkv, G, hd); k_pool/v_pool: HEAD-MAJOR (Hkv, num_blocks,
    block_size, hd); block_tables: (B, nb) int32; cache_len: (B,)."""
    from repro.kernels.paged_decode_attention import paged_gather_dense

    kc, vc = paged_gather_dense(k_pool, v_pool, block_tables)
    return decode_attention_ref(q, kc, vc, cache_len,
                                sliding_window=sliding_window,
                                attention_sinks=attention_sinks,
                                logit_softcap=logit_softcap)


def rwkv6_scan_ref(r, k, v, w, u) -> jax.Array:
    """RWKV6 recurrence oracle.

    r, k, v, w: (B, S, H, P) (w = per-step decay in (0,1), fp32 math);
    u: (H, P) bonus. Returns y: (B, S, H, P), fp32.
      y_t = r_t · (S_{t-1} + u ⊙ k_t ⊗ v_t);  S_t = w_t ⊙ S_{t-1} + k_t ⊗ v_t
    """
    B, S, H, P = r.shape
    rf, kf, vf, wf = [a.astype(jnp.float32) for a in (r, k, v, w)]
    uf = u.astype(jnp.float32)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp  # (B, H, P)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B, H, P, P)
        y = jnp.einsum("bhp,bhpq->bhq", r_t, state + uf[..., None] * kv)
        return w_t[..., :, None] * state + kv, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (rf, kf, vf, wf))
    _, ys = jax.lax.scan(step, jnp.zeros((B, H, P, P), jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3)


def ssm_scan_ref(x, dt, B_in, C_in, decay) -> jax.Array:
    """Mamba2 scalar-decay SSD oracle.

    x: (B, S, H, P) (already dt-scaled inputs), dt unused placeholder kept
    for API parity; B_in, C_in: (B, S, N); decay: (B, S, H) in (0,1].
    Returns y: (B, S, H, P) fp32:  h_t = decay_t h_{t-1} + x_t ⊗ B_t;
    y_t = h_t · C_t.
    """
    Bb, S, H, P = x.shape
    N = B_in.shape[-1]

    def step(h, inp):
        x_t, b_t, c_t, a_t = inp
        h = h * a_t[:, :, None, None] + x_t[..., None] * b_t[:, None, None, :]
        return h, jnp.einsum("bhpn,bn->bhp", h, c_t)

    xs = (x.astype(jnp.float32).transpose(1, 0, 2, 3),
          B_in.astype(jnp.float32).transpose(1, 0, 2),
          C_in.astype(jnp.float32).transpose(1, 0, 2),
          decay.astype(jnp.float32).transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, jnp.zeros((Bb, H, P, N), jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3)
