"""Flash-decode GQA attention Pallas kernel (the paper's BGEMV hot-spot).

TPU adaptation of the attention operator Lamina offloads: the KV sequence is
tiled into `block_k` chunks streamed HBM→VMEM; per chunk the kernel computes
the partial triple (acc, denom, max) and merges it with the running state
using exactly the paper-§4.2.2 combine identity (``core/combine.py``). The
grid's KV dimension is innermost so the output block is revisited and the
scratch accumulators carry across chunks — the single-chip realisation of
split-KV attention, and the same math the cross-chip sequence partition uses.

Layout notes (TPU v5e):
  * k/v blocks are (block_k, hd) with hd padded to the 128-lane register
    width by the wrapper; block_k defaults to 512 → 512×128×2B = 128 KiB per
    operand in VMEM.
  * q is (G, hd) per kv-head (GQA group in sublanes); scores (G, block_k)
    hit the MXU as a skinny matmul.
  * accumulators are fp32 scratch; inputs may be bf16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, lo_ref, mo_ref,
                        acc_ref, m_ref, l_ref, *,
                        block_k: int, sliding_window: int,
                        attention_sinks: int, logit_softcap: float, nb: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (block_k, hd) head-major
    v = v_ref[0, 0].astype(jnp.float32)
    cache_len = len_ref[0]

    pos = kb * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_k), 1)[0]           # (block_k,)
    row_valid = pos < cache_len
    if sliding_window > 0:
        in_window = pos >= (cache_len - sliding_window)
        if attention_sinks > 0:  # StreamingLLM sinks stay attendable
            in_window |= pos < attention_sinks
        row_valid &= in_window
    # S % block_k != 0: the trailing block reads past the cache (the wrapper
    # no longer pads a full copy); zero v under the mask so the 0-weight
    # columns can never contribute Inf/NaN through 0·garbage
    v = jnp.where(row_valid[:, None], v, 0.0)

    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, block_k)
    if logit_softcap > 0.0:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    valid = jnp.broadcast_to(row_valid[None, :], s.shape)
    s = jnp.where(valid, s, NEG_INF)

    # paper §4.2.2 combine: rebase running (acc, l) onto the new max
    m_prev = m_ref[...]                           # (G, 128) broadcast lanes
    m_cur = jnp.max(s, axis=-1, keepdims=True)    # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
    alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])  # (G, 1)
    p = jnp.exp(s - m_new[:, :1])                  # (G, block_k)
    p = jnp.where(valid, p, 0.0)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kb == nb - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)
        lo_ref[0, 0] = l_ref[...]   # partial denominator (for §4.2.2 combine)
        mo_ref[0, 0] = m_ref[...]   # partial max


@functools.partial(jax.jit, static_argnames=("block_k", "sliding_window",
                                             "attention_sinks",
                                             "logit_softcap", "interpret",
                                             "return_partials"))
def decode_attention(q, k_cache, v_cache, cache_len, *, block_k: int = 512,
                     sliding_window: int = 0, attention_sinks: int = 0,
                     logit_softcap: float = 0.0,
                     interpret: bool = False,
                     return_partials: bool = False):
    """q: (B, Hkv, G, hd); k_cache/v_cache: HEAD-MAJOR (B, Hkv, S, hd);
    cache_len: (B,). Returns (B, Hkv, G, hd), or (o, l, m) when
    return_partials — the §4.2.2 triple over the cached subset, mergeable
    with other partials. Head-major KV keeps the (block_k, hd) tile a
    contiguous DMA (§Perf #3)."""
    B, Hkv, G, hd = q.shape
    S = k_cache.shape[2]
    block_k = min(block_k, S)
    # ragged tail (S % block_k != 0) is handled by the grid + in-kernel
    # masking: the trailing BlockSpec tile reads past S (allowed — boundary
    # tiles are logically padded) and the kernel zeroes v / NEG_INFs scores
    # for positions ≥ cache_len, so no full-cache jnp.pad copy is needed
    nb = -(-S // block_k)

    kernel = functools.partial(
        _decode_attn_kernel, block_k=block_k, sliding_window=sliding_window,
        attention_sinks=attention_sinks, logit_softcap=logit_softcap, nb=nb)
    grid = (B, Hkv, nb)
    out, l_out, m_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, kb: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, kb: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, kb: (b, h, kb, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, kb: (b, h, kb, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, G, hd), lambda b, h, kb: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 128), lambda b, h, kb: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 128), lambda b, h, kb: (b, h, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, G, 128), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, G, 128), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),    # acc
            pltpu.VMEM((G, 128), jnp.float32),   # running max (lane bcast)
            pltpu.VMEM((G, 128), jnp.float32),   # running denom
        ],
        interpret=interpret,
    )(cache_len, q, k_cache, v_cache)
    if return_partials:
        return out, l_out[..., 0], m_out[..., 0]
    return out
