"""Mamba2 (scalar-decay SSD) selective-scan Pallas kernel.

Sequence tiled into `chunk` VMEM blocks; the (H, P, N) fp32 state carries in
VMEM scratch across the innermost grid dimension. All heads of one batch
element are processed per grid step so the B_t/C_t projections are shared
across heads (they are head-independent in Mamba2's single-group layout):

    h_t = decay_t ⊙ h_{t-1} + (x_t·dt_t) ⊗ B_t ;   y_t = h_t · C_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(x_ref, b_ref, c_ref, a_ref, y_ref, state_ref, *,
                chunk: int):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    def step(t, h):
        x_t = x_ref[0, t].astype(jnp.float32)      # (H, P)
        b_t = b_ref[0, t].astype(jnp.float32)      # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)      # (N,)
        a_t = a_ref[0, t].astype(jnp.float32)      # (H,)
        h = h * a_t[:, None, None] + x_t[..., None] * b_t[None, None, :]
        y = jnp.einsum("hpn,n->hp", h, c_t,
                       preferred_element_type=jnp.float32)
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    state_ref[...] = jax.lax.fori_loop(0, chunk, step, state_ref[...])


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(x, B_in, C_in, decay, *, chunk: int = 128,
             interpret: bool = False) -> jax.Array:
    """x: (B, S, H, P) dt-scaled inputs; B_in/C_in: (B, S, N);
    decay: (B, S, H). Returns y: (B, S, H, P) fp32."""
    Bb, S, H, P = x.shape
    N = B_in.shape[-1]
    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        x = jnp.pad(x, [(0, 0), (0, pad), (0, 0), (0, 0)])
        B_in = jnp.pad(B_in, [(0, 0), (0, pad), (0, 0)])
        C_in = jnp.pad(C_in, [(0, 0), (0, pad), (0, 0)])
        decay = jnp.pad(decay, [(0, 0), (0, pad), (0, 0)],
                        constant_values=1.0)

    kernel = functools.partial(_ssm_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(Bb, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, H), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, H, P), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, n_chunks * chunk, H, P),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(x, B_in, C_in, decay)
    return y[:, :S]
