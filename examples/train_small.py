"""Train a ~100M-parameter llama-family model for a few hundred steps on the
synthetic corpus, with checkpointing and resume (deliverable b, training
variant). Defaults are CPU-sized; pass --d-model 768 --layers 12 for the
full ~100M run if you have the patience.

  PYTHONPATH=src python examples/train_small.py --steps 200
"""
import argparse
import os
import tempfile


from repro.configs import registry
from repro.data.synthetic import packed_batches
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = registry.get_smoke_config(
        "tinyllama-1.1b", num_layers=args.layers, d_model=args.d_model,
        d_ff=args.d_model * 3, vocab_size=args.vocab,
        num_heads=max(args.d_model // 64, 1),
        num_kv_heads=max(args.d_model // 128, 1))
    from repro.core.costmodel import param_count
    print(f"model: {cfg.num_layers}L d={cfg.d_model} "
          f"({param_count(cfg)/1e6:.1f}M params)")

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                             "repro_train_small")
    data = packed_batches(cfg.vocab_size, args.batch, args.seq, seed=0)
    adamw = opt.AdamWConfig(lr=6e-4, warmup_steps=args.steps // 10,
                            total_steps=args.steps)
    params, state, hist = train(
        cfg, adamw, data, args.steps // 2, log_every=args.steps // 10,
        checkpoint_dir=ckpt_dir, checkpoint_every=args.steps // 2)
    print(f"-- resuming from checkpoint at {ckpt_dir} --")
    tree, step = ckpt.restore(ckpt_dir, {"params": params, "opt": state})
    params, state, hist2 = train(
        cfg, adamw, data, args.steps - args.steps // 2,
        params=tree["params"], state=tree["opt"],
        log_every=args.steps // 10)
    print(f"loss: {hist[0]['loss']:.3f} -> {hist2[-1]['loss']:.3f}")
    assert hist2[-1]["loss"] < hist[0]["loss"]
    print("training example complete.")


if __name__ == "__main__":
    main()
