"""Walk through the paper's machinery end to end:

  1. the automated model converter slices a real transformer block at the
     attention operator (min-cut finds the residual context, Q-Proj hoisted);
  2. the sliced program executes with attention "offloaded" to a worker pool
     (head-level partitioning, per-layer transfer accounting);
  3. the rotational staggered pipeline runs 4 concurrent batches over 3
     model replicas + the shared pool, provably bubble-free;
  4. the same placement decision, declaratively: the unified ``LLMEngine``
     serves one trace twice from a single ``EngineConfig`` knob flip
     (``homogeneous`` vs ``attention_pool``) with token-identical output —
     disaggregation is placement, not a different engine.

  PYTHONPATH=src python examples/disaggregated_decode.py
"""
import jax
import numpy as np

from repro.configs import registry
from repro.core import converter, pipeline
from repro.models import blocks, transformer
from repro.serving import EngineConfig, LLMEngine, Request, SamplingParams
from repro.serving.worker_pool import expected_transfer_bytes


def main():
    cfg = registry.get_smoke_config("llama3-8b")
    w = blocks.init_dense_block(jax.random.PRNGKey(0), cfg)

    print("== 1. automated model converter (paper §4.2) ==")
    g = converter.build_block_graph(cfg, weights=w, batch=4)
    sp = converter.split_at_attention(g)
    print(f"graph: {len(g.order)} ops, {len(g.attention_ops())} attention op")
    for sl in sp.slices:
        print(f"  slice {sl.index}: {sl.program}")
        if sl.context_out:
            print(f"    min-cut context -> next slice: {sl.context_out} "
                  f"({sp.cut_bytes[sl.index]} bytes)")
        if sl.sends:
            print(f"    transfers: {sl.sends}")

    print("\n== 2. sliced execution with offloaded attention ==")
    x = np.random.default_rng(0).standard_normal(
        (4, cfg.d_model)).astype(np.float32)

    sent = {"q": 0, "kv": 0}

    def attention_worker(name, env):
        q, k, v = env["q_proj"], env["k_proj"], env["v_proj"]
        sent["q"] += q.size * 2
        sent["kv"] += (k.size + v.size) * 2
        return np.repeat(v, q.shape[1] // v.shape[1], axis=1)

    trace = []
    env = sp.run({"x": x}, attention_worker, trace=trace)
    print("schedule:", " -> ".join(trace[:8]), "...")
    print(f"bytes to attention pool: q={sent['q']} kv={sent['kv']} "
          f"(paper §3.1 per-token formula for 1 layer: "
          f"{expected_transfer_bytes(cfg.replace(num_layers=1), 4)} B)")
    print(f"output shape: {env['residual2'].shape}")

    print("\n== 3. rotational staggered pipelining (paper §4.3) ==")
    s = pipeline.rotational_schedule(4, 6)
    v = pipeline.validate(s)
    u = pipeline.utilisation(s)
    print(f"4 batches over 3 replicas + shared pool: {v}")
    print(f"utilisation: attn={u['attn']:.3f} " +
          " ".join(f"model:{r}={u[f'model:{r}']:.3f}" for r in range(3)))
    print(f"throughput multiplier vs non-pipelined: "
          f"{pipeline.throughput_speedup(4):.3f}x")

    print("\n== 4. placement as a declarative decision (LLMEngine) ==")
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (5, 9)]
    outs = {}
    for placement in ("homogeneous", "attention_pool"):
        reqs = [Request(prompt=list(p),
                        params=SamplingParams(max_new_tokens=6))
                for p in prompts]
        eng = LLMEngine(cfg, params, EngineConfig(
            placement=placement, max_batch=4, num_blocks=64))
        eng.submit(reqs)
        eng.run()
        outs[placement] = [r.output for r in reqs]
        print(f"  {placement:15s} -> {outs[placement]}")
    print(f"  token-identical across placements: "
          f"{outs['homogeneous'] == outs['attention_pool']}")


if __name__ == "__main__":
    main()
