"""End-to-end serving driver: serve a batched request trace through the
unified ``LLMEngine`` under BOTH placements — ``homogeneous`` (the
vLLM-style baseline) and ``attention_pool`` (Lamina) — with continuous
batching and the paged KV pool, and compare throughput, batch occupancy,
latency percentiles, and per-layer transfer accounting. Placement is the
only thing that changes between the two runs: one engine, one scheduler,
one declarative ``EngineConfig`` knob.

  PYTHONPATH=src python examples/serve_trace.py --trace azure-conv \
      --requests 16
"""
import argparse

import jax

from repro.configs import registry
from repro.data import traces
from repro.models import transformer
from repro.serving import EngineConfig, LLMEngine
from repro.serving.worker_pool import expected_transfer_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--trace", default="azure-conv",
                    choices=list(traces.TRACES))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--scheduler", default="fcfs",
                    choices=["fcfs", "preempt"])
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    print(f"== trace {args.trace} x{args.requests} on reduced {cfg.name} ==")

    results = {}
    for placement in ("homogeneous", "attention_pool"):
        reqs = traces.generate(args.trace, args.requests, cfg.vocab_size,
                               scale=args.scale, seed=0)
        eng = LLMEngine(cfg, params, EngineConfig(
            placement=placement, max_batch=args.max_batch, num_blocks=512,
            scheduler=args.scheduler))
        eng.submit(reqs)
        eng.run()
        s = eng.stats.summary()
        results[placement] = (reqs, eng)
        print(f"{placement:15s} tokens={s['tokens_generated']:5d} "
              f"mean_batch={s['mean_batch']:5.2f} "
              f"throughput={s['throughput_tok_s']:7.1f} tok/s "
              f"tbt_p50={s['tbt_p50_s']*1e3:6.2f} ms "
              f"ttft_p90={s['ttft_p90_s']*1e3:7.2f} ms")

    # identical outputs (the disaggregation is semantically invisible)
    same = all(a.output == b.output
               for a, b in zip(results["homogeneous"][0],
                               results["attention_pool"][0]))
    print(f"outputs identical: {same}")
    eng = results["attention_pool"][1]
    log = eng.pool.log
    per_tok = log.total / max(eng.stats.tokens_generated, 1)
    print(f"lamina per-layer transfers: {log.transfers} "
          f"({log.total/1e6:.2f} MB total, {per_tok:.0f} B/token; "
          f"paper formula {expected_transfer_bytes(cfg, 1)} B/token)")


if __name__ == "__main__":
    main()
