"""End-to-end serving driver (deliverable b): serve a batched request trace
through BOTH engines — the vLLM-style homogeneous baseline and the Lamina
disaggregated engine — with continuous batching and the paged KV pool, and
compare throughput, batch occupancy, and per-layer transfer accounting.

  PYTHONPATH=src python examples/serve_trace.py --trace azure-conv \
      --requests 16
"""
import argparse

import jax

from repro.configs import registry
from repro.data import traces
from repro.models import transformer
from repro.serving.disagg_engine import DisaggEngine, expected_transfer_bytes
from repro.serving.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--trace", default="azure-conv",
                    choices=list(traces.TRACES))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    print(f"== trace {args.trace} x{args.requests} on reduced {cfg.name} ==")

    results = {}
    for name, ctor in (
            ("vllm-baseline", lambda: Engine(
                cfg, params, max_batch=args.max_batch, num_blocks=512)),
            ("lamina", lambda: DisaggEngine(
                cfg, params, max_batch=args.max_batch, num_blocks=512,
                n_attention_workers=2))):
        reqs = traces.generate(args.trace, args.requests, cfg.vocab_size,
                               scale=args.scale, seed=0)
        eng = ctor()
        eng.submit(reqs)
        stats = eng.run()
        results[name] = (reqs, stats, eng)
        print(f"{name:15s} tokens={stats.tokens_generated:5d} "
              f"mean_batch={stats.mean_batch:5.2f} "
              f"throughput={stats.throughput:7.1f} tok/s "
              f"mean_tbt={stats.mean_tbt*1e3:6.2f} ms")

    # identical outputs (the disaggregation is semantically invisible)
    same = all(a.output == b.output
               for a, b in zip(results["vllm-baseline"][0],
                               results["lamina"][0]))
    print(f"outputs identical: {same}")
    eng = results["lamina"][2]
    log = eng.pool.log
    per_tok = log.total / max(eng.stats.tokens_generated, 1)
    print(f"lamina per-layer transfers: {log.transfers} "
          f"({log.total/1e6:.2f} MB total, {per_tok:.0f} B/token; "
          f"paper formula {expected_transfer_bytes(cfg, 1)} B/token)")


if __name__ == "__main__":
    main()
