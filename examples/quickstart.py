"""Quickstart: build a reduced model, train it briefly on the synthetic
corpus, then generate with the serving stack's unified ``LLMEngine`` —
submit a prompt, get a streaming ``RequestHandle``, and watch tokens arrive
as they are decoded over the paged KV pool (the same facade that serves the
disaggregated placements; here it runs the ``homogeneous`` baseline).
``EngineConfig(prefix_sharing=True)`` additionally maps identical prompt
prefixes onto shared refcounted KV blocks (copy-on-write on divergence) —
greedy outputs are bit-identical either way.

  PYTHONPATH=src python examples/quickstart.py [--arch tinyllama-1.1b]
"""
import argparse


from repro.configs import registry
from repro.data.synthetic import packed_batches
from repro.serving import EngineConfig, LLMEngine, SamplingParams
from repro.training import optimizer as opt
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=registry.list_archs())
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    print(f"arch={cfg.name} family={cfg.family} (reduced: "
          f"{cfg.num_layers}L d={cfg.d_model} vocab={cfg.vocab_size})")
    data = packed_batches(cfg.vocab_size, batch=4, seq_len=64, seed=0)
    params, _, hist = train(
        cfg, opt.AdamWConfig(lr=1e-3, warmup_steps=5,
                             total_steps=args.steps),
        data, args.steps, log_every=max(args.steps // 5, 1))
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    prompt = [1, 2, 3, 4, 5]
    engine = LLMEngine(cfg, params, EngineConfig(num_blocks=64))
    handle = engine.generate(prompt, SamplingParams(max_new_tokens=12))
    print("prompt:", prompt)
    print("generated:", end=" ", flush=True)
    for tok in handle:           # tokens stream as the engine decodes
        print(tok, end=" ", flush=True)
    print()


if __name__ == "__main__":
    main()
