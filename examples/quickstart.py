"""Quickstart: build a reduced model, train it briefly on the synthetic
corpus, then generate greedily with the KV-cached decode path.

  PYTHONPATH=src python examples/quickstart.py [--arch tinyllama-1.1b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.data.synthetic import packed_batches
from repro.models import transformer
from repro.training import optimizer as opt
from repro.training.train_loop import train


def generate(params, cfg, prompt_tokens, n_new=16):
    batch = {"tokens": jnp.asarray([prompt_tokens], jnp.int32)}
    logits, cache = transformer.prefill(params, cfg, batch,
                                        max_seq=len(prompt_tokens) + n_new)
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        logits, updates = transformer.decode_step(
            params, cfg, jnp.asarray([out[-1]], jnp.int32), cache)
        cache = transformer.apply_decode_updates(cache, updates)
        out.append(int(jnp.argmax(logits[0])))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b",
                    choices=registry.list_archs())
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = registry.get_smoke_config(args.arch)
    print(f"arch={cfg.name} family={cfg.family} (reduced: "
          f"{cfg.num_layers}L d={cfg.d_model} vocab={cfg.vocab_size})")
    data = packed_batches(cfg.vocab_size, batch=4, seq_len=64, seed=0)
    params, _, hist = train(
        cfg, opt.AdamWConfig(lr=1e-3, warmup_steps=5,
                             total_steps=args.steps),
        data, args.steps, log_every=max(args.steps // 5, 1))
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    prompt = [1, 2, 3, 4, 5]
    toks = generate(params, cfg, prompt, n_new=12)
    print("prompt:", prompt)
    print("generated:", toks)


if __name__ == "__main__":
    main()
